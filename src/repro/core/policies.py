"""Unified placement control plane.

Two pieces, consumed identically by the JAX serving stack and the
event-driven simulator:

* ``PlacementPolicy`` — one interface (``propose(freqs, cluster) ->
  PlacementPlan``) over every placement strategy in the repo: the DanceMoE
  pipeline (Algorithms 1+2) and the paper's baselines (Uniform, Redundance,
  SmartMoE, EPLB). Policies are registered by name so launchers, benchmarks
  and the simulator select them with a string.

* ``PlacementController`` — the single owner of the observe -> place ->
  adopt loop: it ingests activation statistics, periodically asks its
  policy for a candidate plan, applies the Eq.-4 adopt decision
  (``should_migrate``), and records migration events. It absorbs the review
  logic that used to be duplicated between ``serving.scheduler
  .GlobalScheduler`` (batch-clocked, JAX engine) and ``core.migration
  .MigrationController`` (wall-clock, simulator); both survive as thin
  deprecated shims over this class.

The controller is clock-agnostic: ``now`` is any monotonically increasing
scalar (seconds in the simulator, decode rounds in the serving runtime) and
``interval`` is measured in the same units.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Protocol, runtime_checkable

import numpy as np

from repro.core.placement import (PlacementPlan, build_ep_placement,
                                  dancemoe_placement)
from repro.core.stats import ActivationStats


# ---------------------------------------------------------------------------
# Cluster view: what a policy is allowed to know about the hardware
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ClusterView:
    """Capacity summary a placement policy consumes (decoupled from both
    ``ClusterSpec`` and ``EPSpec`` so the same policy object serves the
    simulator and the SPMD runtime)."""
    capacity: np.ndarray                 # [N] total expert-slot budget
    slots_cap: np.ndarray | None = None  # [N] per-(server, layer) slot cap

    @property
    def n(self) -> int:
        return len(self.capacity)

    @staticmethod
    def from_cluster(cluster, profile) -> "ClusterView":
        """From a simulator ``ClusterSpec`` + ``MoEProfile``."""
        cap = cluster.expert_capacity(profile.expert_bytes)
        slots = np.minimum(np.maximum(cap // profile.num_layers, 1),
                           profile.num_experts)
        return ClusterView(capacity=cap, slots_cap=slots)

    @staticmethod
    def from_ep_spec(spec, n_groups: int) -> "ClusterView":
        """From the SPMD runtime's ``EPSpec`` (n_ep ranks x S slots over
        ``n_groups`` MoE layers)."""
        return ClusterView(
            capacity=np.full(spec.n_ep, spec.slots * n_groups),
            slots_cap=np.full(spec.n_ep, spec.slots))

    @staticmethod
    def from_topology(topology, profile, tiered: bool = False
                      ) -> "ClusterView":
        """From a ``repro.serving.net.Topology`` + ``MoEProfile``: each
        server's expert budget comes from its own :class:`ServerProfile`
        memory cap (the heterogeneous analogue of ``from_cluster``).

        ``tiered=True`` budgets each server at its *deepest* expert tier
        (host RAM / modeled disk) instead of its GPU memory, so Algorithm
        1 may legally assign more experts than the GPU holds — the
        ``repro.serving.tiers.TierManager`` decides which subset is
        GPU-resident at any moment."""
        if tiered:
            cap = topology.tiered_expert_budgets(profile.expert_bytes)
        else:
            cap = topology.expert_budgets(profile.expert_bytes)
        slots = np.minimum(np.maximum(cap // profile.num_layers, 1),
                           profile.num_experts)
        return ClusterView(capacity=cap, slots_cap=slots)


# ---------------------------------------------------------------------------
# Policy protocol + registry
# ---------------------------------------------------------------------------

@runtime_checkable
class PlacementPolicy(Protocol):
    """What every placement strategy implements: a pure function from
    observed activation statistics + cluster budgets to a plan."""

    def propose(self, freqs: np.ndarray,
                cluster: ClusterView) -> PlacementPlan:
        """freqs: [L, N, E] normalized activation frequencies."""
        ...


_REGISTRY: dict[str, type] = {}


def register_policy(name: str):
    """Class decorator: register a policy under ``name`` (its
    ``get_policy`` / ``as_policy`` lookup key) and set ``cls.name``."""
    def deco(cls):
        _REGISTRY[name] = cls
        cls.name = name
        return cls
    return deco


def get_policy(name: str, **kwargs) -> PlacementPolicy:
    """Instantiate the registered policy ``name`` (kwargs go to its
    constructor); raises ``KeyError`` listing the known names."""
    if name not in _REGISTRY:
        raise KeyError(f"unknown placement policy {name!r}; "
                       f"available: {sorted(_REGISTRY)}")
    return _REGISTRY[name](**kwargs)


def list_policies() -> tuple[str, ...]:
    """All registered policy names, sorted."""
    return tuple(sorted(_REGISTRY))


@register_policy("dancemoe")
@dataclasses.dataclass
class DanceMoEPolicy:
    """Algorithm 1 + Algorithm 2 (+ spare-slot replication)."""
    fill_spare: bool = True

    def propose(self, freqs, cluster):
        return dancemoe_placement(freqs, cluster.capacity,
                                  cluster.slots_cap,
                                  fill_spare=self.fill_spare)


@register_policy("uniform")
@dataclasses.dataclass
class UniformPolicy:
    """Megatron-style expert parallelism: expert e on server e % N."""

    def propose(self, freqs, cluster):
        from repro.core.baselines import uniform_plan
        L, N, E = freqs.shape
        return uniform_plan(L, N, E, cluster.capacity, cluster.slots_cap)


@register_policy("redundance")
@dataclasses.dataclass
class RedundancePolicy:
    """Uniform coverage + random duplication up to capacity."""
    seed: int = 0

    def propose(self, freqs, cluster):
        from repro.core.baselines import redundance_plan
        L, N, E = freqs.shape
        return redundance_plan(L, N, E, cluster.capacity, cluster.slots_cap,
                               seed=self.seed)


@register_policy("smartmoe")
@dataclasses.dataclass
class SmartMoEPolicy:
    """SmartMoE [ATC'23]-style workload-balanced placement."""

    def propose(self, freqs, cluster):
        from repro.core.baselines import smartmoe_plan
        return smartmoe_plan(freqs, cluster.capacity, cluster.slots_cap)


@register_policy("eplb")
@dataclasses.dataclass
class EPLBPolicy:
    """DeepSeek-V3 Expert Parallelism Load Balancer."""

    def propose(self, freqs, cluster):
        from repro.core.baselines import eplb_plan
        return eplb_plan(freqs, cluster.capacity, cluster.slots_cap)


@dataclasses.dataclass
class FnPolicy:
    """Adapter: a bare ``freqs -> PlacementPlan`` callable as a policy
    (the legacy ``placement_fn`` convention)."""
    fn: Callable[[np.ndarray], PlacementPlan]
    name: str = "fn"

    def propose(self, freqs, cluster):
        return self.fn(freqs)


def as_policy(policy) -> PlacementPolicy:
    """Normalize: policy object | registered name | bare callable."""
    if isinstance(policy, str):
        return get_policy(policy)
    if hasattr(policy, "propose"):
        return policy
    if callable(policy):
        return FnPolicy(policy)
    raise TypeError(f"not a placement policy: {policy!r}")


# ---------------------------------------------------------------------------
# The controller: observe -> place -> adopt (Eq. 4) -> record
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PlacementDecision:
    """One review's outcome: the candidate ``plan``, whether Eq. (4)
    ``adopted`` it, and the pricing diagnostics (``diag``: modeled costs
    in seconds, or ``{"infeasible": ...}`` when Algorithm 1 had no
    feasible assignment)."""

    plan: PlacementPlan
    adopted: bool
    diag: dict
    applied: bool = False     # set by review_and_apply when the adopted
    #                           plan was actually pushed into an engine
    staged: bool = False      # adopted but still transferring over the
    #                           modeled links; ``plan`` is the incumbent


@dataclasses.dataclass
class PlacementController:
    """Single system-wide placement brain (paper Fig. 4, left).

    ``review(now, freqs)`` runs at most once per ``interval`` of the
    caller's clock: it asks the policy for a candidate plan and adopts it
    iff Eq. (4) holds (``C(P') + T_mig < C(P)``). The first review always
    adopts (there is no incumbent to defend) and records an
    ``{"reason": "initial"}`` event — the one code path for what
    ``GlobalScheduler`` and ``MigrationController`` previously each
    implemented with different bookkeeping.

    ``cost=None`` disables the Eq.-4 gate (every review adopts) — useful
    for always-follow policies in ablations.

    **Staged migration** (``topology=`` a ``repro.serving.net.Topology``):
    adopting a plan no longer switches it instantly. The changed experts
    become per-link transfer tasks (serialized per link, overlapped with
    serving — ``net.plan_transfers``/``schedule_transfers``); the
    candidate sits in ``pending`` until ``poll(now)`` observes the
    schedule's makespan elapsed, and only then does ``plan`` change.
    Reviews pause while a migration is in flight. ``clock_rate`` converts
    modeled transfer *seconds* into the caller's clock units (seconds per
    tick; the simulator's seconds clock keeps the default 1.0). The
    initial adoption (no incumbent → nothing to transfer off a live
    server) stays instantaneous.
    """
    policy: PlacementPolicy | Callable | str
    cost: "CostModel | None" = None          # repro.core.migration.CostModel
    #                                          or repro.serving.net
    #                                          .CommCostModel (link-aware)
    cluster: ClusterView | None = None
    interval: float = 300.0                  # caller clock units between
    #                                          reviews: seconds on the sim
    #                                          clock, decode rounds (ticks)
    #                                          on the runtime clock
    stats: ActivationStats | None = None
    plan: PlacementPlan | None = None
    last_review: float | None = None
    events: list = dataclasses.field(default_factory=list)
    topology: "object | None" = None         # repro.serving.net.Topology
    clock_rate: float = 1.0                  # seconds per caller clock unit
    expert_bytes: float | None = None        # transfer sizing fallback when
    #                                          cost= carries no expert_bytes
    pending: "object | None" = None          # net.StagedMigration in flight
    tiers: "object | None" = None            # serving.tiers.TierManager —
    #                                          rebinds tier residency on
    #                                          every plan switch
    tracer: "object | None" = None           # serving.obs.Tracer — every
    #                                          events record doubles as a
    #                                          PLACEMENT_REVIEW instant
    #                                          (full Eq.-4 diag) and staged
    #                                          transfers as TRANSFER_TASK
    #                                          spans; duck-typed so core
    #                                          stays import-free of serving

    def __post_init__(self):
        self.policy = as_policy(self.policy)

    def _record(self, diag: dict) -> None:
        """The one decision-record point: append to ``events`` and mirror
        the full diag (reason, adopted, Eq.-4 cost numbers, staging
        payload) as a control-plane ``PLACEMENT_REVIEW`` trace instant."""
        self.events.append(diag)
        if self.tracer is not None and self.tracer.enabled:
            self.tracer.instant("PLACEMENT_REVIEW", diag.get("time", 0.0),
                                **{k: v for k, v in diag.items()
                                   if k != "time"})

    def _set_plan(self, plan: PlacementPlan) -> None:
        """The one plan-switch point: every adoption path (instant,
        staged completion, fault review) funnels through here so an
        attached :class:`~repro.serving.tiers.TierManager` re-splits the
        new assignments across its tiers in lockstep."""
        self.plan = plan
        if self.tiers is not None:
            self.tiers.bind(plan)

    def _expert_bytes(self) -> float:
        b = self.expert_bytes
        if b is None:
            b = getattr(self.cost, "expert_bytes", None)
        if b is None:
            raise ValueError(
                "staged migration needs the expert weight size: pass "
                "expert_bytes= (or a cost model carrying it) alongside "
                "topology=")
        return float(b)

    def attach_topology(self, topology=None, expert_bytes=None):
        """Reconcile a caller-supplied topology with this controller's —
        the one code path behind ``EdgeCluster``, ``EdgeSimulator`` and
        the runtime backend: adopt the caller's topology when the
        controller has none, hand the controller's back when the caller
        has none, and default the staged-transfer sizing when neither
        ``expert_bytes`` nor the cost model carries it yet. Returns the
        topology in effect."""
        if topology is None:
            topology = self.topology
        elif self.topology is None:
            self.topology = topology
        elif self.topology is not topology:
            # two divergent link models in one run (metering on one,
            # staging/Eq.-4 on the other) would disagree silently
            raise ValueError(
                "controller already has a different topology attached; "
                "share one Topology object between the controller and "
                "the cluster")
        if (self.topology is not None and expert_bytes is not None
                and self.expert_bytes is None
                and getattr(self.cost, "expert_bytes", None) is None):
            self.expert_bytes = float(expert_bytes)
        return topology

    # -- stats ingestion ---------------------------------------------------
    def observe(self, layer_counts: np.ndarray) -> None:
        """layer_counts: [L, N, E] activation counts (JAX engine path)."""
        self._stats().update(np.asarray(layer_counts, np.float64))

    def observe_server(self, server: int, layer_counts: np.ndarray) -> None:
        """layer_counts: [L, E] counts for one server (simulator path)."""
        self._stats().update_server(server, layer_counts)

    def freqs(self) -> np.ndarray:
        return self._stats().freqs()

    def _stats(self) -> ActivationStats:
        if self.stats is None:
            raise ValueError(
                "PlacementController has no ActivationStats attached; pass "
                "stats= at construction or supply freqs= to review()")
        return self.stats

    # -- review ------------------------------------------------------------
    def _effective_cluster(self) -> ClusterView | None:
        """The policy's capacity view minus crashed servers: a dead
        server's expert budget is 0, so no candidate plan places anything
        there. With every server up (or no topology) this is ``cluster``
        itself, so fault-free behavior is bit-identical."""
        cv = self.cluster
        if cv is None or self.topology is None:
            return cv
        up = np.asarray(self.topology.state.up)
        if up.all():
            return cv
        slots = (None if cv.slots_cap is None
                 else np.where(up, cv.slots_cap, 0))
        return ClusterView(capacity=np.where(up, cv.capacity, 0),
                           slots_cap=slots)

    def propose(self, freqs: np.ndarray) -> PlacementPlan:
        return self.policy.propose(freqs, self._effective_cluster())

    def review_due(self, now: float) -> bool:
        if self.pending is not None:        # one migration in flight at a
            return False                    # time; reviews resume after it
        return (self.last_review is None
                or now - self.last_review >= self.interval)

    def _stage(self, now: float, candidate: PlacementPlan):
        """Turn an adopted candidate into an in-flight staged migration
        (returns it; ``poll`` completes it). No transfers needed → adopt
        instantly and return None."""
        from repro.serving import net as _net
        tasks = _net.plan_transfers(self.plan, candidate, self.topology,
                                    self._expert_bytes())
        if not tasks:
            self._set_plan(candidate)
            return None
        seconds = _net.schedule_transfers(tasks, self.topology)
        _net.trace_transfers(self.tracer, tasks, now, self.clock_rate)
        staged = _net.StagedMigration(
            plan=candidate, tasks=tasks, started=now,
            eta=now + seconds / self.clock_rate, seconds=seconds)
        self.pending = staged
        return staged

    def review(self, now: float, freqs: np.ndarray | None = None, *,
               force: bool = False) -> PlacementDecision:
        """One control-loop tick. Returns the (possibly unchanged) active
        plan; ``adopted`` says whether a migration was decided at this
        tick (with a topology attached, the switch itself lands later —
        see ``poll``)."""
        if self.pending is not None:
            # one migration in flight at a time — even a forced review
            # must not overwrite the pending plan (its transfers would be
            # dropped mid-flight and MIGRATION_COMPLETED never emitted)
            return PlacementDecision(self.plan, False,
                                     {"reason": "migration-in-flight"})
        if not force and not self.review_due(now):
            return PlacementDecision(self.plan, False, {"reason": "interval"})
        if freqs is None:
            freqs = self.freqs()
        self.last_review = now
        candidate = self.propose(freqs)
        if self.plan is None:
            adopt, diag = True, {"reason": "initial"}
        elif self.cost is None:
            adopt, diag = True, {"reason": "no-cost-model"}
        else:
            from repro.core.migration import should_migrate
            adopt, diag = should_migrate(self.plan, candidate, freqs,
                                         self.cost)
        diag = dict(diag)
        diag["time"] = now
        diag["adopted"] = adopt
        staged = None
        if adopt:
            if self.plan is not None and self.topology is not None:
                staged = self._stage(now, candidate)
                if staged is not None:
                    diag["staged"] = True
                    diag["eta"] = staged.eta
                    diag["transfers"] = len(staged.tasks)
                    diag["transfer_seconds"] = staged.seconds
                    diag["transfer_bytes"] = staged.nbytes
            else:
                self._set_plan(candidate)
        self._record(diag)
        return PlacementDecision(self.plan, adopt, diag,
                                 staged=staged is not None)

    # -- fault handling ----------------------------------------------------
    def pending_affected(self) -> bool:
        """True when the in-flight staged migration can no longer complete
        as scheduled: a transfer task's source or destination server died,
        or an inter-server task's link degraded after the schedule was
        priced (its eta is now a lie). Such a migration must be aborted
        and re-planned, never completed on a ghost server."""
        p = self.pending
        if p is None or self.topology is None:
            return False
        st = self.topology.state
        for t in p.tasks:
            if not st.up[t.src] or not st.up[t.dst]:
                return True
            if t.src != t.dst and st.bw_factor[t.src, t.dst] < 1.0:
                return True
        return False

    def abort_pending(self, now: float, cause: str = "fault"):
        """Drop the in-flight staged migration (its transfers are lost;
        the incumbent plan stays active) and record a
        ``migration-aborted`` event. Returns the aborted
        ``net.StagedMigration`` (or None when nothing was pending)."""
        p = self.pending
        if p is None:
            return None
        self.pending = None
        self._record({
            "reason": "migration-aborted", "time": now, "adopted": False,
            "abort_cause": cause, "staged_at": p.started, "eta": p.eta,
            "transfers": len(p.tasks), "transfer_seconds": p.seconds,
            "transfer_bytes": p.nbytes,
        })
        return p

    def fault_review(self, now: float, freqs: np.ndarray | None = None, *,
                     cause: str = "fault") -> PlacementDecision:
        """Immediate, ungated re-placement after a capacity-changing
        fault. Unlike ``review(force=True)`` this (a) aborts any pending
        migration first — its candidate was computed against the
        pre-fault fabric — and (b) skips the Eq.-4 gate: after a crash
        the incumbent references capacity that no longer exists, so
        ``should_migrate`` would be defending a ghost. The candidate is
        proposed against the liveness-masked capacity view and staged
        over the surviving links as usual."""
        if self.pending is not None:
            self.abort_pending(now, cause=cause)
        if freqs is None:
            freqs = self.freqs()
        self.last_review = now
        try:
            candidate = self.propose(freqs)
        except RuntimeError as e:
            # the surviving capacity cannot cover every expert (Algorithm
            # 1 coverage is infeasible): keep the incumbent plan rather
            # than crash the control plane mid-failover — the uncovered
            # experts stay unservable until capacity returns
            diag = {"reason": cause, "time": now, "adopted": False,
                    "fault_review": True, "infeasible": str(e)}
            self._record(diag)
            return PlacementDecision(self.plan, False, diag, staged=False)
        diag = {"reason": cause, "time": now, "adopted": True,
                "fault_review": True}
        staged = None
        if self.plan is not None and self.topology is not None:
            staged = self._stage(now, candidate)
            if staged is not None:
                diag["staged"] = True
                diag["eta"] = staged.eta
                diag["transfers"] = len(staged.tasks)
                diag["transfer_seconds"] = staged.seconds
                diag["transfer_bytes"] = staged.nbytes
        else:
            self._set_plan(candidate)
        self._record(diag)
        return PlacementDecision(self.plan, True, diag,
                                 staged=staged is not None)

    def poll(self, now: float):
        """Complete the in-flight staged migration once its modeled
        transfers have finished: the pending plan becomes the active plan
        and a ``migration-complete`` event is recorded. Returns the
        completed ``net.StagedMigration`` (or ``None``: nothing pending,
        or transfers still running)."""
        p = self.pending
        if p is None or now < p.eta:
            return None
        self.pending = None
        self._set_plan(p.plan)
        self._record({
            "reason": "migration-complete", "time": now, "adopted": False,
            "staged_at": p.started, "eta": p.eta,
            "transfers": len(p.tasks), "transfer_seconds": p.seconds,
            "transfer_bytes": p.nbytes,
        })
        return p

    def _mesh_distance(self, engine):
        """Topology-derived nearest-replica distance matrix for the
        engine's EP routing tables, when the topology maps 1:1 onto the
        EP ranks (else the default ring distance applies)."""
        if self.topology is None:
            return None
        n_ep = engine.rt.ep_spec.n_ep
        if self.topology.n != n_ep:
            return None
        if hasattr(self.cost, "invocation_seconds"):
            return self.cost.invocation_seconds()
        return self.topology.distance()

    def _apply_plan(self, engine) -> bool:
        """Push the active plan into a serving engine (EP slot re-gather
        + table swap); returns False for engines without EP placement.
        With a :class:`TierManager` attached, GPU-tier experts fill the
        engine's physical slots first (back-tier assignments overflow the
        slot budget and are served via fetch/remote instead)."""
        if getattr(engine.rt, "ep_spec", None) is None:
            return False
        priority = (self.tiers.slot_priority()
                    if self.tiers is not None else None)
        engine.migrate(build_ep_placement(
            self.plan, engine.rt.ep_spec.slots,
            mesh_distance=self._mesh_distance(engine),
            priority=priority))
        return True

    def fault_review_and_apply(self, now: float, engine, *,
                               cause: str = "fault") -> PlacementDecision:
        """``fault_review`` + immediate engine apply when the adopted
        plan is not staged (the runtime-backend analogue of
        ``review_and_apply`` for the fault path)."""
        dec = self.fault_review(now, cause=cause)
        if dec.adopted and not dec.staged:
            dec.applied = self._apply_plan(engine)
        return dec

    def review_and_apply(self, now: float, engine) -> PlacementDecision | None:
        """Review on the caller's clock and apply an adopted plan to a
        serving engine (EP slot re-gather + table swap via
        ``engine.migrate``). The one code path behind both the
        ``ServingRuntime`` decode-round clock and the ``EdgeCluster``
        façade's tick clock. With a topology attached, an adopted plan is
        *staged* first and pushed into the engine only on the later call
        whose ``now`` has passed the transfer schedule's makespan.
        Returns the decision when a review ran or a staged migration
        completed, ``None`` otherwise."""
        completed = self.poll(now)
        if completed is not None:
            dec = PlacementDecision(self.plan, True, dict(self.events[-1]))
            dec.applied = self._apply_plan(engine)
            return dec
        if not self.review_due(now):
            return None
        dec = self.review(now)
        if dec.adopted and not dec.staged:
            dec.applied = self._apply_plan(engine)  # callers log migrations
            #                                         off this flag
        return dec

    @property
    def migrations(self) -> list:
        """Adopted non-initial reviews (actual placement changes)."""
        return [e for e in self.events
                if e["adopted"] and e.get("reason") != "initial"]

"""Unified placement control plane.

Two pieces, consumed identically by the JAX serving stack and the
event-driven simulator:

* ``PlacementPolicy`` — one interface (``propose(freqs, cluster) ->
  PlacementPlan``) over every placement strategy in the repo: the DanceMoE
  pipeline (Algorithms 1+2) and the paper's baselines (Uniform, Redundance,
  SmartMoE, EPLB). Policies are registered by name so launchers, benchmarks
  and the simulator select them with a string.

* ``PlacementController`` — the single owner of the observe -> place ->
  adopt loop: it ingests activation statistics, periodically asks its
  policy for a candidate plan, applies the Eq.-4 adopt decision
  (``should_migrate``), and records migration events. It absorbs the review
  logic that used to be duplicated between ``serving.scheduler
  .GlobalScheduler`` (batch-clocked, JAX engine) and ``core.migration
  .MigrationController`` (wall-clock, simulator); both survive as thin
  deprecated shims over this class.

The controller is clock-agnostic: ``now`` is any monotonically increasing
scalar (seconds in the simulator, decode rounds in the serving runtime) and
``interval`` is measured in the same units.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Protocol, runtime_checkable

import numpy as np

from repro.core.placement import (PlacementPlan, build_ep_placement,
                                  dancemoe_placement)
from repro.core.stats import ActivationStats


# ---------------------------------------------------------------------------
# Cluster view: what a policy is allowed to know about the hardware
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ClusterView:
    """Capacity summary a placement policy consumes (decoupled from both
    ``ClusterSpec`` and ``EPSpec`` so the same policy object serves the
    simulator and the SPMD runtime)."""
    capacity: np.ndarray                 # [N] total expert-slot budget
    slots_cap: np.ndarray | None = None  # [N] per-(server, layer) slot cap

    @property
    def n(self) -> int:
        return len(self.capacity)

    @staticmethod
    def from_cluster(cluster, profile) -> "ClusterView":
        """From a simulator ``ClusterSpec`` + ``MoEProfile``."""
        cap = cluster.expert_capacity(profile.expert_bytes)
        slots = np.minimum(np.maximum(cap // profile.num_layers, 1),
                           profile.num_experts)
        return ClusterView(capacity=cap, slots_cap=slots)

    @staticmethod
    def from_ep_spec(spec, n_groups: int) -> "ClusterView":
        """From the SPMD runtime's ``EPSpec`` (n_ep ranks x S slots over
        ``n_groups`` MoE layers)."""
        return ClusterView(
            capacity=np.full(spec.n_ep, spec.slots * n_groups),
            slots_cap=np.full(spec.n_ep, spec.slots))


# ---------------------------------------------------------------------------
# Policy protocol + registry
# ---------------------------------------------------------------------------

@runtime_checkable
class PlacementPolicy(Protocol):
    def propose(self, freqs: np.ndarray,
                cluster: ClusterView) -> PlacementPlan:
        """freqs: [L, N, E] normalized activation frequencies."""
        ...


_REGISTRY: dict[str, type] = {}


def register_policy(name: str):
    def deco(cls):
        _REGISTRY[name] = cls
        cls.name = name
        return cls
    return deco


def get_policy(name: str, **kwargs) -> PlacementPolicy:
    if name not in _REGISTRY:
        raise KeyError(f"unknown placement policy {name!r}; "
                       f"available: {sorted(_REGISTRY)}")
    return _REGISTRY[name](**kwargs)


def list_policies() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


@register_policy("dancemoe")
@dataclasses.dataclass
class DanceMoEPolicy:
    """Algorithm 1 + Algorithm 2 (+ spare-slot replication)."""
    fill_spare: bool = True

    def propose(self, freqs, cluster):
        return dancemoe_placement(freqs, cluster.capacity,
                                  cluster.slots_cap,
                                  fill_spare=self.fill_spare)


@register_policy("uniform")
@dataclasses.dataclass
class UniformPolicy:
    """Megatron-style expert parallelism: expert e on server e % N."""

    def propose(self, freqs, cluster):
        from repro.core.baselines import uniform_plan
        L, N, E = freqs.shape
        return uniform_plan(L, N, E, cluster.capacity, cluster.slots_cap)


@register_policy("redundance")
@dataclasses.dataclass
class RedundancePolicy:
    """Uniform coverage + random duplication up to capacity."""
    seed: int = 0

    def propose(self, freqs, cluster):
        from repro.core.baselines import redundance_plan
        L, N, E = freqs.shape
        return redundance_plan(L, N, E, cluster.capacity, cluster.slots_cap,
                               seed=self.seed)


@register_policy("smartmoe")
@dataclasses.dataclass
class SmartMoEPolicy:
    """SmartMoE [ATC'23]-style workload-balanced placement."""

    def propose(self, freqs, cluster):
        from repro.core.baselines import smartmoe_plan
        return smartmoe_plan(freqs, cluster.capacity, cluster.slots_cap)


@register_policy("eplb")
@dataclasses.dataclass
class EPLBPolicy:
    """DeepSeek-V3 Expert Parallelism Load Balancer."""

    def propose(self, freqs, cluster):
        from repro.core.baselines import eplb_plan
        return eplb_plan(freqs, cluster.capacity, cluster.slots_cap)


@dataclasses.dataclass
class FnPolicy:
    """Adapter: a bare ``freqs -> PlacementPlan`` callable as a policy
    (the legacy ``placement_fn`` convention)."""
    fn: Callable[[np.ndarray], PlacementPlan]
    name: str = "fn"

    def propose(self, freqs, cluster):
        return self.fn(freqs)


def as_policy(policy) -> PlacementPolicy:
    """Normalize: policy object | registered name | bare callable."""
    if isinstance(policy, str):
        return get_policy(policy)
    if hasattr(policy, "propose"):
        return policy
    if callable(policy):
        return FnPolicy(policy)
    raise TypeError(f"not a placement policy: {policy!r}")


# ---------------------------------------------------------------------------
# The controller: observe -> place -> adopt (Eq. 4) -> record
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PlacementDecision:
    plan: PlacementPlan
    adopted: bool
    diag: dict
    applied: bool = False     # set by review_and_apply when the adopted
    #                           plan was actually pushed into an engine


@dataclasses.dataclass
class PlacementController:
    """Single system-wide placement brain (paper Fig. 4, left).

    ``review(now, freqs)`` runs at most once per ``interval`` of the
    caller's clock: it asks the policy for a candidate plan and adopts it
    iff Eq. (4) holds (``C(P') + T_mig < C(P)``). The first review always
    adopts (there is no incumbent to defend) and records an
    ``{"reason": "initial"}`` event — the one code path for what
    ``GlobalScheduler`` and ``MigrationController`` previously each
    implemented with different bookkeeping.

    ``cost=None`` disables the Eq.-4 gate (every review adopts) — useful
    for always-follow policies in ablations.
    """
    policy: PlacementPolicy | Callable | str
    cost: "CostModel | None" = None          # repro.core.migration.CostModel
    cluster: ClusterView | None = None
    interval: float = 300.0
    stats: ActivationStats | None = None
    plan: PlacementPlan | None = None
    last_review: float | None = None
    events: list = dataclasses.field(default_factory=list)

    def __post_init__(self):
        self.policy = as_policy(self.policy)

    # -- stats ingestion ---------------------------------------------------
    def observe(self, layer_counts: np.ndarray) -> None:
        """layer_counts: [L, N, E] activation counts (JAX engine path)."""
        self._stats().update(np.asarray(layer_counts, np.float64))

    def observe_server(self, server: int, layer_counts: np.ndarray) -> None:
        """layer_counts: [L, E] counts for one server (simulator path)."""
        self._stats().update_server(server, layer_counts)

    def freqs(self) -> np.ndarray:
        return self._stats().freqs()

    def _stats(self) -> ActivationStats:
        if self.stats is None:
            raise ValueError(
                "PlacementController has no ActivationStats attached; pass "
                "stats= at construction or supply freqs= to review()")
        return self.stats

    # -- review ------------------------------------------------------------
    def propose(self, freqs: np.ndarray) -> PlacementPlan:
        return self.policy.propose(freqs, self.cluster)

    def review_due(self, now: float) -> bool:
        return (self.last_review is None
                or now - self.last_review >= self.interval)

    def review(self, now: float, freqs: np.ndarray | None = None, *,
               force: bool = False) -> PlacementDecision:
        """One control-loop tick. Returns the (possibly unchanged) active
        plan; ``adopted`` says whether a migration happened at this tick."""
        if not force and not self.review_due(now):
            return PlacementDecision(self.plan, False, {"reason": "interval"})
        if freqs is None:
            freqs = self.freqs()
        self.last_review = now
        candidate = self.propose(freqs)
        if self.plan is None:
            adopt, diag = True, {"reason": "initial"}
        elif self.cost is None:
            adopt, diag = True, {"reason": "no-cost-model"}
        else:
            from repro.core.migration import should_migrate
            adopt, diag = should_migrate(self.plan, candidate, freqs,
                                         self.cost)
        diag = dict(diag)
        diag["time"] = now
        diag["adopted"] = adopt
        self.events.append(diag)
        if adopt:
            self.plan = candidate
        return PlacementDecision(self.plan, adopt, diag)

    def review_and_apply(self, now: float, engine) -> PlacementDecision | None:
        """Review on the caller's clock and apply an adopted plan to a
        serving engine (EP slot re-gather + table swap via
        ``engine.migrate``). The one code path behind both the
        ``ServingRuntime`` decode-round clock and the ``EdgeCluster``
        façade's tick clock. Returns the decision when a review ran,
        ``None`` when the interval has not elapsed."""
        if not self.review_due(now):
            return None
        dec = self.review(now)
        if dec.adopted and getattr(engine.rt, "ep_spec", None) is not None:
            engine.migrate(build_ep_placement(dec.plan,
                                              engine.rt.ep_spec.slots))
            dec.applied = True      # callers log migrations off this flag
        return dec

    @property
    def migrations(self) -> list:
        """Adopted non-initial reviews (actual placement changes)."""
        return [e for e in self.events
                if e["adopted"] and e.get("reason") != "initial"]

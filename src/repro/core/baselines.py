"""Baseline expert-placement strategies from the paper's evaluation:

* Uniform     — Megatron-style expert parallelism: expert e on server e % N.
* Redundance  — uniform coverage + random duplication up to capacity.
* SmartMoE    — load-balancing placement module (workload-balanced, no
                replication), re-implemented after SmartMoE [ATC'23].
* EPLB        — DeepSeek-V3's Expert Parallelism Load Balancer: replicate
                high-load experts proportionally to load, then
                longest-processing-time bin packing onto servers;
                re-implemented for heterogeneous capacities as in the paper.

All return ``PlacementPlan`` so they are drop-in interchangeable with
``dancemoe_placement`` for the runtime, the simulator and the benchmarks.
"""
from __future__ import annotations

import numpy as np

from repro.core.placement import PlacementPlan


def _layer_caps(capacity: np.ndarray, L: int,
                slots_cap: np.ndarray | None) -> np.ndarray:
    """Per-(server, layer) slot caps [N]: either the SPMD cap or an even
    split of the server budget across layers."""
    cap = np.asarray(capacity, int)
    if slots_cap is not None:
        return np.asarray(slots_cap, int)
    return np.maximum(cap // L, 1)


def uniform_plan(L: int, N: int, E: int, capacity=None,
                 slots_cap=None) -> PlacementPlan:
    assign = [[[e for e in range(E) if e % N == n] for n in range(N)]
              for _ in range(L)]
    counts = np.array([[len(assign[l][n]) for n in range(N)]
                       for l in range(L)])
    return PlacementPlan(assign=assign, counts=counts, num_experts=E)


def redundance_plan(L: int, N: int, E: int, capacity: np.ndarray,
                    slots_cap=None, seed: int = 0) -> PlacementPlan:
    """Uniform coverage, then random duplication until capacity is full."""
    rng = np.random.default_rng(seed)
    caps = _layer_caps(capacity, L, slots_cap)
    assign = []
    for l in range(L):
        layer = [[e for e in range(E) if e % N == n] for n in range(N)]
        for n in range(N):
            room = int(caps[n]) - len(layer[n])
            if room > 0:
                pool = [e for e in range(E) if e not in layer[n]]
                extra = rng.choice(pool, size=min(room, len(pool)),
                                   replace=False)
                layer[n] += [int(e) for e in extra]
        assign.append(layer)
    counts = np.array([[len(assign[l][n]) for n in range(N)]
                       for l in range(L)])
    return PlacementPlan(assign=assign, counts=counts, num_experts=E)


def smartmoe_plan(freqs: np.ndarray, capacity: np.ndarray,
                  slots_cap=None) -> PlacementPlan:
    """Workload-balanced placement: experts sorted by global load, each
    assigned (one copy) to the least-loaded feasible server."""
    L, N, E = freqs.shape
    caps = _layer_caps(capacity, L, slots_cap)
    assign = []
    for l in range(L):
        load_e = freqs[l].sum(0)                    # global per-expert load
        server_load = np.zeros(N)
        layer = [[] for _ in range(N)]
        for e in np.argsort(-load_e, kind="stable"):
            order = np.argsort(server_load, kind="stable")
            placed = False
            for n in order:
                if len(layer[n]) < caps[n]:
                    layer[n].append(int(e))
                    server_load[n] += load_e[e]
                    placed = True
                    break
            if not placed:
                raise RuntimeError("smartmoe: insufficient capacity")
        assign.append(layer)
    counts = np.array([[len(assign[l][n]) for n in range(N)]
                       for l in range(L)])
    return PlacementPlan(assign=assign, counts=counts, num_experts=E)


def eplb_plan(freqs: np.ndarray, capacity: np.ndarray,
              slots_cap=None) -> PlacementPlan:
    """EPLB: replicate high-load experts and balance via LPT packing.

    Replica counts: each expert gets >= 1; the spare slot budget is spread
    proportionally to global load. Instances (expert, load/replicas) are
    then packed longest-first onto the least-loaded server with room.
    """
    L, N, E = freqs.shape
    caps = _layer_caps(capacity, L, slots_cap)
    budget = int(caps.sum())                       # slots per layer
    assign = []
    for l in range(L):
        load_e = freqs[l].sum(0)
        load_e = load_e / max(load_e.sum(), 1e-12)
        spare = max(budget - E, 0)
        extra = np.floor(load_e * spare).astype(int)
        # distribute remaining spare greedily by fractional part
        rem = spare - extra.sum()
        if rem > 0:
            frac = load_e * spare - extra
            for e in np.argsort(-frac, kind="stable")[:rem]:
                extra[e] += 1
        replicas = 1 + extra
        inst_load = load_e / replicas
        instances = [(e, inst_load[e]) for e in range(E)
                     for _ in range(replicas[e])]
        instances.sort(key=lambda t: -t[1])        # LPT
        server_load = np.zeros(N)
        layer = [[] for _ in range(N)]
        for e, w in instances:
            order = np.argsort(server_load, kind="stable")
            for n in order:
                if len(layer[n]) < caps[n] and e not in layer[n]:
                    layer[n].append(int(e))
                    server_load[n] += w
                    break
        assign.append(layer)
    counts = np.array([[len(assign[l][n]) for n in range(N)]
                       for l in range(L)])
    return PlacementPlan(assign=assign, counts=counts, num_experts=E)

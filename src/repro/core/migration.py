"""Lightweight expert migration (Sec. III-C.3).

Eq. (3): T_mig(P, P') = sum over changed placement entries of m_e / speed.
Eq. (4): adopt P' iff  C(P') + T_mig(P, P') < C(P),
where C(.) converts the Eq.-2 proxy (expected remote invocations) into
seconds using the measured per-invocation remote cost and the request rate
over the evaluation horizon — exactly the paper's "historical communication
and computation time as estimation metrics".
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.placement import (PlacementPlan, iter_added_experts,
                                  remote_cost)


@dataclasses.dataclass
class CostModel:
    """Converts proxy-objective units into seconds."""
    expert_bytes: float                 # m_e
    activation_bytes: float             # hidden-state transfer per invocation
    bandwidth: float                    # bytes/s between servers
    io_speed: np.ndarray | float = 1e9  # per-server weight-load bytes/s
    per_call_overhead: float = 1e-3     # network round-trip + queuing (s)
    tokens_per_horizon: float = 1e4     # expected token-layer invocations
                                        # until the next placement review

    def remote_invocation_time(self) -> float:
        return (2.0 * self.activation_bytes / self.bandwidth
                + self.per_call_overhead)

    def comm_cost_seconds(self, plan: PlacementPlan,
                          freqs: np.ndarray) -> float:
        """C(P) in seconds over the horizon (Eq. 2 × cost/invocation)."""
        return (remote_cost(plan, freqs) / freqs.shape[0]
                * self.tokens_per_horizon * self.remote_invocation_time())


def migration_time(old: PlacementPlan, new: PlacementPlan,
                   cost: CostModel) -> float:
    """Eq. (3): bytes moved / IO speed, per changed placement entry."""
    speeds = np.broadcast_to(np.asarray(cost.io_speed, float),
                             (len(new.assign[0]),))
    return sum(cost.expert_bytes / speeds[n]
               for _, n, _ in iter_added_experts(old, new))


def should_migrate(old: PlacementPlan, new: PlacementPlan,
                   freqs: np.ndarray, cost: CostModel
                   ) -> tuple[bool, dict]:
    """Eq. (4) decision. Returns (adopt?, diagnostics).

    ``cost`` may be this module's uniform :class:`CostModel` or any object
    with the same ``comm_cost_seconds`` surface; a cost model that also
    provides ``migration_seconds(old, new)`` (the link-aware
    ``repro.serving.net.CommCostModel`` prices the staged transfer
    schedule's makespan) overrides the uniform Eq.-3 estimate."""
    c_old = cost.comm_cost_seconds(old, freqs)
    c_new = cost.comm_cost_seconds(new, freqs)
    if hasattr(cost, "migration_seconds"):
        t_mig = cost.migration_seconds(old, new)
    else:
        t_mig = migration_time(old, new, cost)
    return c_new + t_mig < c_old, {
        "C_old": c_old, "C_new": c_new, "T_mig": t_mig,
        "gain": c_old - c_new - t_mig,
    }


def _placement_controller():
    # deferred import: policies imports should_migrate from this module
    from repro.core.policies import PlacementController
    return PlacementController


class MigrationController:
    """DEPRECATED shim — use ``repro.core.policies.PlacementController``.

    Kept for the legacy ``maybe_migrate(now, freqs) -> (plan, adopted)``
    API; all review/adopt logic lives in the unified controller."""

    def __init__(self, placement_fn, cost: CostModel,
                 interval: float = 300.0):
        import warnings
        warnings.warn(
            "MigrationController is deprecated: use "
            "core.policies.PlacementController (review(now, freqs)) instead",
            DeprecationWarning, stacklevel=2)
        self.ctrl = _placement_controller()(
            policy=placement_fn, cost=cost, interval=interval)

    @property
    def current(self) -> PlacementPlan | None:
        return self.ctrl.plan

    @property
    def history(self) -> list:
        """Non-initial review diagnostics (legacy semantics: the initial
        adoption was never recorded here)."""
        return [e for e in self.ctrl.events if e.get("reason") != "initial"]

    def maybe_migrate(self, now: float, freqs: np.ndarray
                      ) -> tuple[PlacementPlan, bool]:
        dec = self.ctrl.review(now, freqs)
        return dec.plan, dec.adopted

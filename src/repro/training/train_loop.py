"""Training step / loop: causal-LM loss + MoE aux losses, grad clipping,
pluggable optimizer. The same ``train_step`` is what the multi-pod dry-run
lowers for the ``train_4k`` input shape."""
from __future__ import annotations

import time

import jax

from repro.models import transformer as tr
from repro.optim.adamw import Optimizer, clip_by_global_norm


def make_train_step(rt: tr.Runtime, opt: Optimizer, *,
                    max_grad_norm: float = 1.0, aux_weight: float = 0.01):
    """Returns train_step(params, opt_state, tokens, targets, placement)."""

    def train_step(params, opt_state, tokens, targets, placement=None):
        def loss_of(p):
            loss, metrics = tr.loss_fn(rt, p, tokens, targets,
                                       placement=placement,
                                       aux_weight=aux_weight)
            return loss, metrics

        (loss, metrics), grads = jax.value_and_grad(
            loss_of, has_aux=True)(params)
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        params, opt_state = opt.update(grads, opt_state, params)
        out = {"loss": loss, "grad_norm": gnorm,
               "ce_loss": metrics["ce_loss"]}
        if "aux_loss" in metrics:
            out["aux_loss"] = metrics["aux_loss"]
            out["local_frac"] = metrics["local_frac"]
        return params, opt_state, out

    return train_step


def train_loop(rt: tr.Runtime, params, opt: Optimizer, batches, *,
               placement=None, log_every: int = 10, jit: bool = True):
    """batches: iterable of (tokens, targets). Returns (params, history)."""
    step_fn = make_train_step(rt, opt)
    if jit:
        step_fn = jax.jit(step_fn)
    opt_state = opt.init(params)
    history = []
    t0 = time.time()
    for i, (tokens, targets) in enumerate(batches):
        params, opt_state, m = step_fn(params, opt_state, tokens, targets,
                                       placement)
        if i % log_every == 0 or i < 3:
            m = {k: float(v) for k, v in m.items()}
            m["step"] = i
            m["wall"] = time.time() - t0
            history.append(m)
    return params, opt_state, history

"""Optimizers, pure-pytree (no optax dependency).

* ``adamw``    — standard AdamW, used by the small training examples.
* ``adafactor``— factored second moments (Shazeer & Stern), used by the
  production dry-run train steps: at 100B+ parameters on 16 GB/chip v5e,
  unfactored fp32 Adam moments alone exceed HBM — factored states are the
  standard TPU memory adaptation (noted in DESIGN.md).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple[Any, Any]]  # (g, state, p) ->
                                                        # (new_p, new_state)


def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        s = jnp.asarray(step, jnp.float32)
        warm = s / jnp.maximum(warmup, 1)
        prog = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return base_lr * jnp.where(s < warmup, warm, cos)
    return lr


def clip_by_global_norm(grads, max_norm: float):
    g2 = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(grads))
    norm = jnp.sqrt(g2)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), norm


def adamw(lr=1e-3, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.01,
          schedule=None) -> Optimizer:
    lr_fn = schedule if schedule is not None else (lambda s: lr)

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = lr_fn(step)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            mh = m / (1 - b1 ** step.astype(jnp.float32))
            vh = v / (1 - b2 ** step.astype(jnp.float32))
            delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * \
                p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * delta).astype(p.dtype), \
                m, v

        flat = jax.tree.map(upd, grads, state["m"], state["v"], params)
        new_p = jax.tree.map(lambda t: t[0], flat,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda t: t[1], flat,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda t: t[2], flat,
                             is_leaf=lambda x: isinstance(x, tuple))
        return new_p, {"m": new_m, "v": new_v, "step": step}

    return Optimizer(init=init, update=update)


def adafactor(lr=1e-2, decay=0.8, eps=1e-30, clip_threshold=1.0,
              schedule=None) -> Optimizer:
    """Factored second-moment optimizer (memory ~ O(rows + cols))."""
    lr_fn = schedule if schedule is not None else (lambda s: lr)

    def _leaf_state(p):
        if p.ndim >= 2:
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}

    def init(params):
        # per-leaf factored states as a flat list (leaf order of the tree)
        return {"f": [_leaf_state(p) for p in jax.tree.leaves(params)],
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        beta = 1.0 - (step.astype(jnp.float32)) ** (-decay)
        lr_t = lr_fn(step)

        def upd(g, s, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if p.ndim >= 2:
                vr = beta * s["vr"] + (1 - beta) * g2.mean(-1)
                vc = beta * s["vc"] + (1 - beta) * g2.mean(-2)
                denom = jnp.maximum(vr.mean(-1, keepdims=True), eps)
                pre = (vr / denom)[..., None] * vc[..., None, :]
                u = g * jax.lax.rsqrt(jnp.maximum(pre, eps))
                ns = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = g * jax.lax.rsqrt(jnp.maximum(v, eps))
                ns = {"v": v}
            rms = jnp.sqrt(jnp.mean(u * u) + eps)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            return (p.astype(jnp.float32) - lr_t * u).astype(p.dtype), ns

        leaves_g, treedef = jax.tree.flatten(grads)
        leaves_p = jax.tree.leaves(params)
        outs = [upd(g, s, p)
                for g, s, p in zip(leaves_g, state["f"], leaves_p)]
        new_p = jax.tree.unflatten(treedef, [o[0] for o in outs])
        return new_p, {"f": [o[1] for o in outs], "step": step}

    return Optimizer(init=init, update=update)

"""Production mesh construction.

Target: TPU v5e, 256 chips/pod. Single-pod mesh (16, 16) = ("data",
"model"); multi-pod (2, 16, 16) = ("pod", "data", "model"). Functions, not
module constants — importing this module never touches jax device state.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5: explicit axis types on the mesh
    from jax.sharding import AxisType
    _AXIS_KW = True
except ImportError:  # older jax: meshes are implicitly Auto
    AxisType = None
    _AXIS_KW = False


def _make_mesh(shape, axes):
    if _AXIS_KW:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def set_mesh(mesh):
    """Version-compat default-mesh context: ``jax.set_mesh`` on jax >= 0.5;
    on 0.4.x the ``Mesh`` object itself is the (resource-env) context
    manager and all our sharding is explicit anyway."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_test_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (possibly fake) devices exist."""
    return _make_mesh((data, model), ("data", "model"))


# v5e hardware constants used by the roofline analysis
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (~per-chip injection)
CHIPS_PER_POD = 256

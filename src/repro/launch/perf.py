import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Perf-iteration driver (§Perf): lower a (arch, shape) pair under a named
variant (sharding layout / remat policy / MoE capacity override), derive the
roofline terms via depth differencing, and append the record to
results/perf/<arch>__<shape>.jsonl — the hypothesis -> change -> measure log.

  PYTHONPATH=src python -m repro.launch.perf --arch tinyllama-1.1b \
      --shape train_4k --variant cp --note "replicated weights + ctx parallel"
"""
import argparse
import json
import time
from pathlib import Path

import jax

from repro.configs import get_config
from repro.configs.base import INPUT_SHAPES
from repro.launch import mesh as mesh_lib
from repro.launch.dryrun import (_analyse, _lower_compile, build_lowerable,
                                 depth_variant)
from repro.launch.roofline import roofline_report

VARIANTS = {
    # name -> kwargs for build_lowerable
    "baseline": {},
    "sp": {"layout": "sp"},
    "cp": {"layout": "cp"},
    "sp+dots": {"layout": "sp", "remat_policy": "dots"},
    "cp+dots": {"layout": "cp", "remat_policy": "dots"},
    "tp+dots": {"remat_policy": "dots"},
    "cp+dots+kv": {"layout": "cp", "remat_policy": "dots+kv"},
    "sp+dots+kv": {"layout": "sp", "remat_policy": "dots+kv"},
    "sp+cf1": {"layout": "sp", "moe_overrides": {"capacity_factor": 1.0}},
    "sp+cf05": {"layout": "sp", "moe_overrides": {"capacity_factor": 0.5}},
    "cf1": {"moe_overrides": {"capacity_factor": 1.0}},
    "fsdp": {"layout": "fsdp"},
    "kv8": {"kv_quant": True},
    "fsdp+dots+kv+cf1": {"layout": "fsdp", "remat_policy": "dots+kv",
                         "moe_overrides": {"capacity_factor": 1.0}},
    "fsdp+cf1": {"layout": "fsdp", "moe_overrides": {"capacity_factor": 1.0}},
    "fsdp+dots+kv": {"layout": "fsdp", "remat_policy": "dots+kv"},
}


def run_variant(arch: str, shape_name: str, variant: str,
                note: str = "", out_dir: str = "results/perf") -> dict:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh = mesh_lib.make_production_mesh()
    kw = VARIANTS[variant]
    rec = {"arch": arch, "shape": shape_name, "variant": variant,
           "note": note, "ok": False}
    t0 = time.time()
    try:
        # full scanned compile: proof + memory analysis
        fn, kwargs = build_lowerable(cfg, shape, mesh, **kw)
        donate = ("cache",) if "cache" in kwargs else ()
        with mesh_lib.set_mesh(mesh):
            compiled = jax.jit(fn, donate_argnames=donate).lower(
                **kwargs).compile()
        if donate:
            rec["donated_cache"] = True
        mem = compiled.memory_analysis()
        rec["argument_size_in_bytes"] = int(mem.argument_size_in_bytes or 0)
        rec["output_size_in_bytes"] = int(mem.output_size_in_bytes or 0)
        rec["temp_size_in_bytes"] = int(mem.temp_size_in_bytes or 0)
        del compiled
        # exact per-device terms via unrolled depth differencing
        _, n_groups = cfg.layer_pattern()
        a1 = _analyse(_lower_compile(depth_variant(cfg, 1), shape, mesh,
                                     scan_layers=False, **kw))
        a2 = _analyse(_lower_compile(depth_variant(cfg, 2), shape, mesh,
                                     scan_layers=False, **kw))

        def extrap(x1, x2):
            per = max(x2 - x1, 0.0)
            return max(x1 - per, 0.0) + per * n_groups
        coll = {}
        for k in a1["collectives"]:
            if k == "total_bytes":
                continue
            coll[k] = {"bytes": int(extrap(a1["collectives"][k]["bytes"],
                                           a2["collectives"][k]["bytes"])),
                       "count": int(extrap(a1["collectives"][k]["count"],
                                           a2["collectives"][k]["count"]))}
        coll["total_bytes"] = sum(v["bytes"] for v in coll.values()
                                  if isinstance(v, dict))
        rec["hlo_flops"] = extrap(a1["flops"], a2["flops"])
        rec["hlo_bytes"] = extrap(a1["bytes"], a2["bytes"])
        rec["collectives"] = coll
        rec["roofline"] = roofline_report(rec, cfg, shape,
                                          n_chips=256)
        rec["ok"] = True
    except Exception as e:
        import traceback
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-3000:]
    rec["total_s"] = round(time.time() - t0, 1)
    Path(out_dir).mkdir(parents=True, exist_ok=True)
    with open(Path(out_dir, f"{arch}__{shape_name}.jsonl"), "a") as f:
        f.write(json.dumps(rec, default=str) + "\n")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", required=True, choices=list(VARIANTS))
    ap.add_argument("--note", default="")
    args = ap.parse_args()
    rec = run_variant(args.arch, args.shape, args.variant, args.note)
    if rec["ok"]:
        ro = rec["roofline"]
        print(f"[OK] {args.arch} {args.shape} {args.variant}: "
              f"compute={ro['compute_s']*1e3:.1f}ms "
              f"memory={ro['memory_s']*1e3:.1f}ms "
              f"collective={ro['collective_s']*1e3:.1f}ms "
              f"dominant={ro['dominant']} "
              f"coll_GB={rec['collectives']['total_bytes']/1e9:.1f}")
    else:
        print(f"[FAIL] {rec.get('error')}\n{rec.get('traceback', '')[-1500:]}")


if __name__ == "__main__":
    main()

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, with ShapeDtypeStruct inputs (no allocation).

The two lines above MUST stay first: jax locks the device count on first
initialisation, and the dry-run needs 512 placeholder host devices to build
the (2, 16, 16) production mesh. Smoke tests and benchmarks do NOT import
this module — they see the real single CPU device.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out DIR]
"""
import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import INPUT_SHAPES, InputShape, ModelConfig
from repro.configs import get_config
from repro.core.placement import build_ep_placement, dancemoe_placement
from repro.launch import mesh as mesh_lib
from repro.launch.roofline import collective_bytes_from_hlo, roofline_report
from repro.models import moe as moe_mod
from repro.models import sharding as sh
from repro.models import transformer as tr
from repro.optim.adamw import adafactor
from repro.training.train_loop import make_train_step

ASSIGNED_ARCHS = [
    "starcoder2-3b", "qwen2-vl-72b", "tinyllama-1.1b", "falcon-mamba-7b",
    "zamba2-2.7b", "musicgen-large", "command-r-plus-104b",
    "llama4-maverick-400b-a17b", "yi-6b", "phi3.5-moe-42b-a6.6b",
]


def ep_axes_for(cfg: ModelConfig) -> tuple[str, ...]:
    """MoE archs shard experts over the full in-pod device set."""
    return ("data", "model")


def make_runtime(cfg: ModelConfig, shape: InputShape, mesh, *,
                 moe_overrides: dict | None = None,
                 scan_layers: bool = True, layout: str = "tp",
                 remat_policy: str = "none",
                 kv_quant: bool = False) -> tr.Runtime:
    window = cfg.sliding_window if shape.name == "long_500k" else 0
    if cfg.family in ("ssm",):
        window = 0
    ep_spec = None
    if cfg.is_moe:
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        n_batch = int(np.prod([sizes[a] for a in sizes if a != "model"]))
        if shape.kind == "train" or shape.kind == "prefill":
            rows = shape.global_batch * shape.seq_len // (
                sizes["data"] * sizes["model"] * sizes.get("pod", 1))
        else:
            rows = max(shape.global_batch // max(n_batch, 1), 1)
        kw = dict(ep_axes=ep_axes_for(cfg), rows_per_rank=max(rows, 1),
                  capacity_factor=2.0)
        if shape.kind == "decode":
            btok = max(shape.global_batch // sizes.get("pod", 1), 1)
            kw["slot_capacity"] = max(
                16, int(np.ceil(btok * cfg.top_k / cfg.num_experts * 8)))
        if moe_overrides:
            kw.update(moe_overrides)
        ep_spec = moe_mod.EPSpec.build(mesh, cfg, **kw)
    return tr.Runtime(
        cfg=cfg, mesh=mesh,
        moe_impl="ep" if cfg.is_moe else "dense",
        ep_spec=ep_spec, dtype=jnp.bfloat16, window=window,
        scan_layers=scan_layers, layout=layout, remat_policy=remat_policy,
        kv_quant=kv_quant,
        cache_seq_sharded=(shape.name == "long_500k" and window == 0
                           and cfg.has_attention),
    )


def _sds(tree, spec_tree, mesh):
    """ShapeDtypeStruct pytree with NamedShardings attached."""
    def one(x, s):
        sp = sh._feasible_spec(mesh, x.shape, s)
        return jax.ShapeDtypeStruct(x.shape, x.dtype,
                                    sharding=NamedSharding(mesh, sp))
    return jax.tree.map(one, tree, spec_tree)


def placement_specs(cfg: ModelConfig, rt: tr.Runtime):
    """Stacked per-layer placement tables (device arrays; tiny)."""
    spec = rt.ep_spec
    E = cfg.num_experts
    _, n_groups = cfg.layer_pattern()
    freqs = np.random.default_rng(0).dirichlet(
        np.full(E, 0.5), size=(n_groups, spec.n_ep))
    cap = np.full(spec.n_ep, spec.slots * n_groups)
    plan = dancemoe_placement(freqs, cap, np.full(spec.n_ep, spec.slots))
    return build_ep_placement(plan, spec.slots)


def input_specs(cfg: ModelConfig, shape: InputShape, rt: tr.Runtime, mesh):
    """ShapeDtypeStruct stand-ins for every model input of this shape."""
    b_axes = tuple(a for a in mesh.axis_names if a != "model")
    B, T = shape.global_batch, shape.seq_len
    seq_ax = "model" if rt.layout in ("sp", "cp", "fsdp") else None
    out = {}
    if shape.kind == "train":
        tok = jax.ShapeDtypeStruct((B, T), jnp.int32)
        out["tokens"] = _sds(tok, P(b_axes, seq_ax), mesh)
        out["targets"] = _sds(tok, P(b_axes, seq_ax), mesh)
    elif shape.kind == "prefill":
        if cfg.frontend != "none":
            # modality stub: precomputed patch/frame embeddings
            emb = jax.ShapeDtypeStruct((B, T, cfg.d_model), jnp.bfloat16)
            out["embeds"] = _sds(emb, P(b_axes, None, None), mesh)
        else:
            tok = jax.ShapeDtypeStruct((B, T), jnp.int32)
            out["tokens"] = _sds(tok, P(b_axes, None), mesh)
    else:  # decode: one token against a seq_len cache
        cache = jax.eval_shape(
            lambda: tr.init_cache(rt, B, T, dtype=jnp.bfloat16))
        specs = sh.cache_pspecs(rt, seq_sharded=rt.cache_seq_sharded)
        out["cache"] = _sds(cache, specs, mesh)
        tok = jax.ShapeDtypeStruct((B, 1), jnp.int32)
        out["tokens"] = _sds(tok, P(b_axes, None), mesh)
        out["pos"] = jax.ShapeDtypeStruct((), jnp.int32)
    return out


def build_lowerable(cfg: ModelConfig, shape: InputShape, mesh,
                    scan_layers: bool = True, layout: str = "tp",
                    remat_policy: str = "none",
                    moe_overrides: dict | None = None,
                    kv_quant: bool = False):
    """Returns (jitted_fn, kwargs-of-ShapeDtypeStructs)."""
    rt = make_runtime(cfg, shape, mesh, scan_layers=scan_layers,
                      layout=layout, remat_policy=remat_policy,
                      moe_overrides=moe_overrides, kv_quant=kv_quant)
    pspec = lambda p: sh.pspecs_for(rt, p)
    params = jax.eval_shape(
        lambda: tr.init_params(rt, jax.random.PRNGKey(0)))
    params = _sds(params, pspec(params), mesh)
    kwargs = {"params": params}
    kwargs.update(input_specs(cfg, shape, rt, mesh))
    placement = None
    if cfg.is_moe:
        placement = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(
                a.shape, a.dtype, sharding=NamedSharding(mesh, P())),
            placement_specs(cfg, rt))
        kwargs["placement"] = placement

    if shape.kind == "train":
        opt = adafactor(schedule=None)
        step = make_train_step(rt, opt)
        opt_state = jax.eval_shape(
            lambda: opt.init(jax.tree.map(
                lambda s: jnp.zeros(s.shape, s.dtype), params)))
        # factored states inherit the leading dims of their parameter spec
        flat_specs = jax.tree.leaves(pspec(params))

        def fact_spec(st, psp):
            if "vr" in st:
                return {"vr": P(*tuple(psp)[:-1]) if len(tuple(psp)) else P(),
                        "vc": P(*(tuple(psp)[:-2] + tuple(psp)[-1:]))}
            return {"v": psp}
        f_specs = [fact_spec(s, p) for s, p in
                   zip(opt_state["f"], flat_specs)]
        opt_specs = {"f": f_specs, "step": P()}
        kwargs = {"params": params,
                  "opt_state": _sds(opt_state, opt_specs, mesh),
                  "tokens": kwargs["tokens"], "targets": kwargs["targets"]}
        if placement is not None:
            kwargs["placement"] = placement

        def fn(params, opt_state, tokens, targets, placement=None):
            new_p, new_s, metrics = step(params, opt_state, tokens, targets,
                                         placement)
            return new_p, new_s, metrics["loss"]
        return fn, kwargs

    if shape.kind == "prefill":
        def fn(params, tokens=None, embeds=None, placement=None):
            logits, cache, _ = tr.prefill(rt, params, tokens=tokens,
                                          embeds=embeds, placement=placement)
            return logits, cache
        return fn, kwargs

    def fn(params, cache, tokens, pos, placement=None):
        logits, new_cache, _ = tr.decode_step(rt, params, cache, tokens, pos,
                                              placement)
        return logits, new_cache
    return fn, kwargs


def _unit_layers(cfg: ModelConfig) -> int:
    """Layers in one scan group (the depth-differencing unit)."""
    if cfg.family == "hybrid":
        return cfg.attn_every
    if cfg.family == "moe":
        return cfg.moe_every
    return 1


def depth_variant(cfg: ModelConfig, n_units: int) -> ModelConfig:
    return dataclasses.replace(cfg, num_layers=_unit_layers(cfg) * n_units)


def _analyse(compiled) -> dict:
    cost = compiled.cost_analysis() or {}
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "collectives": collective_bytes_from_hlo(compiled.as_text())}


def _lower_compile(cfg, shape, mesh, scan_layers=True, **kw):
    fn, kwargs = build_lowerable(cfg, shape, mesh, scan_layers=scan_layers,
                                 **kw)
    with mesh_lib.set_mesh(mesh):
        lowered = jax.jit(fn).lower(**kwargs)
        return lowered.compile()


def depth_diff_analysis(cfg, shape, mesh, **build_kw) -> dict:
    """Exact full-depth per-device cost terms via depth differencing.

    XLA's cost analysis counts a scanned layer body once, so the scanned
    full model under-reports flops/bytes/collectives by ~n_groups. We lower
    UNROLLED 1-group and 2-group variants (both cheap to compile), take
    per_group = T(2) - T(1) and outside = T(1) - per_group, and extrapolate
    derived_full = outside + per_group * n_groups. Exact because every group
    lowers to identical HLO."""
    _, n_groups = cfg.layer_pattern()
    a1 = _analyse(_lower_compile(depth_variant(cfg, 1), shape, mesh,
                                 scan_layers=False, **build_kw))
    a2 = _analyse(_lower_compile(depth_variant(cfg, 2), shape, mesh,
                                 scan_layers=False, **build_kw))

    def extrap(x1, x2):
        per = max(x2 - x1, 0.0)
        outside = max(x1 - per, 0.0)
        return outside + per * n_groups

    coll = {}
    for k in a1["collectives"]:
        if k == "total_bytes":
            continue
        coll[k] = {
            "bytes": int(extrap(a1["collectives"][k]["bytes"],
                                a2["collectives"][k]["bytes"])),
            "count": int(extrap(a1["collectives"][k]["count"],
                                a2["collectives"][k]["count"])),
        }
    coll["total_bytes"] = sum(v["bytes"] for v in coll.values()
                              if isinstance(v, dict))
    return {"flops": extrap(a1["flops"], a2["flops"]),
            "bytes": extrap(a1["bytes"], a2["bytes"]),
            "collectives": coll,
            "depth1": a1, "depth2": a2}


def best_layout(cfg: ModelConfig, shape: InputShape) -> dict:
    """Best-known beyond-paper configuration per (arch, shape) from the
    §Perf hillclimb: cp for small models, fsdp (+placement-aware capacity)
    for large ones on train/prefill; decode is already memory-bound under
    the default layout. SSM archs keep tp (channel-sharded scan needs
    model-axis weights)."""
    if shape.kind == "decode" or cfg.family in ("ssm", "hybrid"):
        return {}
    kw: dict = {}
    if cfg.param_count() < 4e9:
        kw["layout"] = "cp"
    else:
        kw["layout"] = "fsdp"
    if shape.kind == "train":
        kw["remat_policy"] = "dots+kv"
    if cfg.is_moe:
        kw["moe_overrides"] = {"capacity_factor": 1.0}
    return kw


def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            out_dir: str = "results/dryrun", save_hlo: bool = False,
            depth_diff: bool = True, optimized: bool = False) -> dict:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    build_kw = best_layout(cfg, shape) if optimized else {}
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(mesh.devices.shape))
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "x".join(map(str, mesh.devices.shape)),
           "chips": n_chips, "ok": False, "build_kw": str(build_kw)}
    t0 = time.time()
    try:
        # 1) the deliverable: full model, scanned layers, lower + compile
        fn, kwargs = build_lowerable(cfg, shape, mesh, **build_kw)
        with mesh_lib.set_mesh(mesh):
            lowered = jax.jit(fn).lower(**kwargs)
            rec["lower_s"] = round(time.time() - t0, 1)
            t1 = time.time()
            compiled = lowered.compile()
            rec["compile_s"] = round(time.time() - t1, 1)
        mem = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes"):
            rec[k] = int(getattr(mem, k, 0) or 0)
        scanned = _analyse(compiled)
        rec["hlo_flops_scanned"] = scanned["flops"]
        rec["hlo_bytes_scanned"] = scanned["bytes"]
        rec["collectives_scanned"] = scanned["collectives"]
        if save_hlo:
            Path(out_dir, f"{arch}__{shape_name}__{rec['mesh']}.hlo.txt"
                 ).write_text(compiled.as_text())
        del compiled

        # 2) exact per-device terms via depth differencing
        if depth_diff:
            dd = depth_diff_analysis(cfg, shape, mesh, **build_kw)
            rec["hlo_flops"] = dd["flops"]
            rec["hlo_bytes"] = dd["bytes"]
            rec["collectives"] = dd["collectives"]
        else:
            rec["hlo_flops"] = scanned["flops"]
            rec["hlo_bytes"] = scanned["bytes"]
            rec["collectives"] = scanned["collectives"]
        rec["roofline"] = roofline_report(rec, cfg, shape, n_chips=n_chips)
        rec["ok"] = True
    except Exception as e:  # record failures — they are bugs to fix
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["total_s"] = round(time.time() - t0, 1)
    Path(out_dir).mkdir(parents=True, exist_ok=True)
    Path(out_dir, f"{arch}__{shape_name}__{rec['mesh']}.json").write_text(
        json.dumps(rec, indent=1, default=str))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-done", action="store_true")
    ap.add_argument("--optimized", action="store_true",
                    help="apply best-known layout per pair (see EXPERIMENTS"
                         " §Perf); write records to --out")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else ASSIGNED_ARCHS
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                mesh_tag = "2x16x16" if mp else "16x16"
                done = Path(args.out, f"{arch}__{shape}__{mesh_tag}.json")
                if args.skip_done and done.exists():
                    prev = json.loads(done.read_text())
                    if prev.get("ok"):
                        print(f"[skip] {arch} {shape} {mesh_tag}")
                        continue
                rec = run_one(arch, shape, multi_pod=mp, out_dir=args.out,
                              optimized=args.optimized)
                status = "OK" if rec["ok"] else f"FAIL {rec.get('error')}"
                print(f"[{status}] {arch} {shape} {mesh_tag} "
                      f"({rec['total_s']}s)", flush=True)


if __name__ == "__main__":
    main()

"""Production training launcher.

On a real TPU slice this runs the same `train_step` the dry-run lowers; on
CPU it runs the reduced variant of the selected architecture so every config
is executable everywhere.

  PYTHONPATH=src python -m repro.launch.train --arch yi-6b --steps 20
  PYTHONPATH=src python -m repro.launch.train --arch phi3.5-moe-42b-a6.6b \
      --steps 10 --batch 4 --seq 128
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.io import save_checkpoint
from repro.configs import get_config, list_configs
from repro.data.pipeline import train_batches
from repro.models import transformer as tr
from repro.optim.adamw import adamw, cosine_schedule
from repro.training.train_loop import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(list_configs()))
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--full", action="store_true",
                    help="use the full (not reduced) config — TPU slices")
    ap.add_argument("--ckpt", default="")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    rt = tr.Runtime(cfg=cfg, moe_impl="dense")
    params = tr.init_params(rt, jax.random.PRNGKey(0))
    print(f"{cfg.name}: "
          f"{sum(p.size for p in jax.tree.leaves(params))/1e6:.1f}M params")
    opt = adamw(schedule=cosine_schedule(args.lr, 10, args.steps))
    step_fn = jax.jit(make_train_step(rt, opt))
    opt_state = opt.init(params)
    t0 = time.time()
    for i, (tok, tgt) in enumerate(train_batches(
            cfg.vocab_size, args.batch, args.seq, args.steps)):
        params, opt_state, m = step_fn(params, opt_state, jnp.asarray(tok),
                                       jnp.asarray(tgt))
        if i % 10 == 0 or i == args.steps - 1:
            print(f"step {i:4d} loss={float(m['loss']):.4f} "
                  f"({(time.time()-t0)/(i+1):.2f}s/step)")
    if args.ckpt:
        save_checkpoint(args.ckpt, params, step=args.steps)
        print(f"saved {args.ckpt}")


if __name__ == "__main__":
    main()

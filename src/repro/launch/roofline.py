"""Roofline analysis from compiled dry-run artifacts (deliverable g).

Three terms per (arch x shape x mesh), all in seconds-per-step-per-chip:

  compute    = HLO_FLOPs / peak_FLOPs          (cost_analysis is per-device)
  memory     = HLO_bytes / HBM_bw
  collective = collective_bytes / ICI_bw

collective_bytes is NOT in cost_analysis: we parse the optimized HLO and sum
the result-shape bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute (all-reduce counted twice: reduce+broadcast
phases). This slightly over-counts a2a/ag by the local shard (factor
(n-1)/n) — a documented, placement-independent approximation; the
placement-aware *effective* a2a bytes (what DanceMoE actually optimises) are
reported separately by the perf harness using measured local fractions.
"""
from __future__ import annotations

import re


from repro.launch import mesh as mesh_lib

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_COLL_RE = re.compile(
    r"=\s*(?:\([^)]*\)\s*)?((?:[a-z0-9]+\[[^\]]*\](?:,\s*)?)+)?\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_LINE_RE = re.compile(
    r"^\s*\S+\s*=\s*(.+?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(([^\n]*)", re.M)
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_from_hlo(hlo: str) -> dict:
    """Sum result-shape bytes per collective kind from optimized HLO text."""
    out = {k: {"bytes": 0, "count": 0}
           for k in ("all-gather", "all-reduce", "reduce-scatter",
                     "all-to-all", "collective-permute")}
    for m in _LINE_RE.finditer(hlo):
        shape_str, kind, rest = m.group(1), m.group(2), m.group(4) or ""
        b = _shape_bytes(shape_str)
        if kind == "all-reduce":
            mult = 2              # reduce + broadcast phases
        elif kind == "reduce-scatter":
            # result is the post-scatter shard: moved bytes ~= input size
            g = _GROUPS_RE.search(rest)
            mult = int(g.group(2)) if g else 1
        else:
            mult = 1
        out[kind]["bytes"] += b * mult
        out[kind]["count"] += 1
    out["total_bytes"] = sum(v["bytes"] for k, v in out.items()
                             if isinstance(v, dict))
    return out


def model_flops(cfg, shape) -> float:
    """6·N_active·D per processed token (2·N·D for pure inference steps)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch      # decode: 1 token/seq


def roofline_report(rec: dict, cfg, shape, *, n_chips: int) -> dict:
    """Three-term roofline, per device per step.

    compute_s    — depth-differenced HLO FLOPs / peak (exact).
    memory_s     — structural HBM traffic: per-device argument bytes
                   (weights + optimizer state + KV cache, from
                   memory_analysis) x passes + outputs. This is the tight
                   bound; `memory_s_hlo` (per-op bytes accessed) is also
                   reported but double-counts fusion-internal operands.
    collective_s — parsed HLO collective bytes / ICI bw.
    """
    compute_t = rec["hlo_flops"] / mesh_lib.PEAK_FLOPS
    passes = 3.0 if shape.kind == "train" else 1.0
    out_bytes = rec.get("output_size_in_bytes", 0)
    if rec.get("donated_cache"):
        out_bytes = max(out_bytes - rec.get("argument_size_in_bytes", 0), 0)
    struct_bytes = (passes * rec.get("argument_size_in_bytes", 0)
                    + out_bytes)
    memory_t = struct_bytes / mesh_lib.HBM_BW
    memory_t_hlo = rec["hlo_bytes"] / mesh_lib.HBM_BW
    coll_bytes = rec["collectives"]["total_bytes"]
    collective_t = coll_bytes / mesh_lib.ICI_BW
    terms = {"compute_s": compute_t, "memory_s": memory_t,
             "collective_s": collective_t}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    useful = mf / max(rec["hlo_flops"] * n_chips, 1.0)
    return {
        **terms,
        "memory_s_hlo": memory_t_hlo,
        "dominant": dominant.removesuffix("_s"),
        "model_flops_global": mf,
        "useful_flops_ratio": useful,
        "step_time_lower_bound_s": max(terms.values()),
        "mfu_bound": mf / max(n_chips * mesh_lib.PEAK_FLOPS
                              * max(terms.values()), 1e-30),
    }

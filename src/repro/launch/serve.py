"""Production serving launcher: batched prefill + decode for a selected
architecture (reduced variant on CPU; full config on TPU slices), with the
DanceMoE placement pipeline active for MoE architectures.

  PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b --steps 8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, list_configs
from repro.core.placement import build_ep_placement, dancemoe_placement
from repro.data.pipeline import TaskTokenSource
from repro.launch.mesh import make_test_mesh
from repro.models import moe as M
from repro.models import transformer as tr
from repro.serving.engine import ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(list_configs()))
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt", type=int, default=32)
    ap.add_argument("--steps", type=int, default=8)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    mesh = make_test_mesh(1, 1)
    if cfg.is_moe:
        spec = M.EPSpec.build(mesh, cfg, ep_axes=("model",),
                              slots=cfg.num_experts, capacity=8192,
                              slot_capacity=16384)
        rt = tr.Runtime(cfg=cfg, mesh=mesh, moe_impl="ep", ep_spec=spec)
        pl = M.uniform_placement(spec.n_ep, spec.slots, cfg.num_experts)
        _, n_groups = cfg.layer_pattern()
        pls = tr.stack_placement(pl, n_groups)
    else:
        rt = tr.Runtime(cfg=cfg, mesh=mesh)
        pls = None
    params = tr.init_params(rt, jax.random.PRNGKey(0))
    engine = ServingEngine(rt=rt, params=params, placement=pls,
                           max_len=args.prompt + args.steps + 8)
    src = TaskTokenSource("serve", cfg.vocab_size, seed=0)
    t0 = time.time()
    if cfg.frontend != "none":
        print(f"{cfg.name}: modality frontend is stubbed; serving over "
              "token prompts against the decoder backbone")
    gen, info = engine.generate(src.sample(args.batch, args.prompt),
                                steps=args.steps)
    dt = time.time() - t0
    print(f"{cfg.name}: generated {gen.shape} tokens in {dt:.1f}s "
          f"({args.batch * args.steps / dt:.1f} tok/s) "
          f"local_ratio={info['local_frac']:.3f}")


if __name__ == "__main__":
    main()

"""Production serving launcher: continuous-batching runtime for a selected
architecture (reduced variant on CPU; full config on TPU slices), with the
unified placement control plane active for MoE architectures.

  PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b --steps 8

Requests are submitted as a stream (staggered into the queue) and served by
``ServingRuntime``: chunked prefill interleaved with decode rounds over a
paged KV block pool (``--block-size`` / ``--blocks``; ``--dense-pool``
restores the legacy fixed-row pool), with the ``--policy`` placement policy
reviewed periodically by the ``PlacementController`` (Eq.-4 adopt
decision).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, list_configs
from repro.core.migration import CostModel
from repro.core.policies import (ClusterView, PlacementController,
                                 get_policy, list_policies)
from repro.data.pipeline import TaskTokenSource
from repro.launch.mesh import make_test_mesh
from repro.models import moe as M
from repro.models import transformer as tr
from repro.serving.api import Request
from repro.serving.engine import ServingEngine
from repro.serving.obs import Tracer
from repro.serving.runtime import ServingRuntime


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(list_configs()))
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt", type=int, default=32)
    ap.add_argument("--steps", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4,
                    help="decode batch width (dense mode: also the KV rows)")
    ap.add_argument("--block-size", type=int, default=16,
                    help="paged KV pool: positions per physical block")
    ap.add_argument("--blocks", type=int, default=None,
                    help="paged KV pool: physical blocks incl. the null "
                    "block (default: match the dense pool's KV memory)")
    ap.add_argument("--dense-pool", action="store_true",
                    help="legacy dense per-slot KV pool (no paging / "
                    "chunked prefill)")
    ap.add_argument("--prefix-cache", default=True,
                    action=argparse.BooleanOptionalAction,
                    help="radix prefix cache: reuse KV pages across "
                    "requests sharing a prompt prefix (paged pool only; "
                    "--no-prefix-cache disables)")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="tokens of system prompt shared by all requests "
                    "(demonstrates prefix-cache hits; 0 = disjoint prompts)")
    ap.add_argument("--warmup", action="store_true",
                    help="AOT-compile the full compaction bucket ladder "
                    "before serving and run the zero-stall decode loop "
                    "(paged pool only; pays compile time up front)")
    ap.add_argument("--policy", default="dancemoe", choices=list_policies())
    ap.add_argument("--review-rounds", type=int, default=16,
                    help="placement review period in decode rounds")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="record per-request/system spans and export the "
                    "Chrome-trace JSON here (open at ui.perfetto.dev; "
                    "inspect with tools/trace_view.py)")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    if args.shared_prefix and args.shared_prefix >= args.prompt:
        ap.error(f"--shared-prefix ({args.shared_prefix}) must be smaller "
                 f"than --prompt ({args.prompt}): the shared system prompt "
                 "is part of the per-request prompt budget")

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    mesh = make_test_mesh(1, 1)
    controller = None
    if cfg.is_moe:
        spec = M.EPSpec.build(mesh, cfg, ep_axes=("model",),
                              slots=cfg.num_experts, capacity=8192,
                              slot_capacity=16384)
        rt = tr.Runtime(cfg=cfg, mesh=mesh, moe_impl="ep", ep_spec=spec)
        pl = M.uniform_placement(spec.n_ep, spec.slots, cfg.num_experts)
        _, n_groups = cfg.layer_pattern()
        pls = tr.stack_placement(pl, n_groups)
        cm = CostModel(expert_bytes=3 * cfg.d_model * cfg.d_ff * 2,
                       activation_bytes=cfg.d_model * 2, bandwidth=62.5e6,
                       tokens_per_horizon=1e5)
        controller = PlacementController(
            policy=get_policy(args.policy), cost=cm,
            cluster=ClusterView.from_ep_spec(spec, n_groups),
            interval=args.review_rounds)
        # dense master copy: live migration re-gathers expert slots from it
        rt_dense = tr.Runtime(cfg=cfg, mesh=mesh, moe_impl="dense")
        params_dense = tr.init_params(rt_dense, jax.random.PRNGKey(0))
        params = dict(params_dense)
        params["groups"] = M.regather_ep_groups(params_dense["groups"], pls,
                                                n_groups)
        dense_master = params_dense["groups"]
    else:
        rt = tr.Runtime(cfg=cfg, mesh=mesh)
        pls = None
        params = tr.init_params(rt, jax.random.PRNGKey(0))
        dense_master = None
    engine = ServingEngine(rt=rt, params=params, placement=pls,
                           dense_master=dense_master,
                           max_len=args.prompt + args.steps + 8)
    if args.warmup and args.dense_pool:
        ap.error("--warmup needs the paged pool (drop --dense-pool)")
    tracer = Tracer(clock="ticks") if args.trace_out else None
    if controller is not None and tracer is not None:
        controller.tracer = tracer          # PLACEMENT_REVIEW decisions
    runtime = ServingRuntime(engine, max_slots=args.slots,
                             controller=controller,
                             paged=False if args.dense_pool else None,
                             block_size=args.block_size,
                             n_blocks=args.blocks,
                             prefix_cache=args.prefix_cache,
                             warmup=args.warmup,
                             warmup_origins="untagged",
                             tracer=tracer)
    if args.warmup:
        print(f"warmup: {runtime.executables_compiled} executables in "
              f"{runtime.warmup_seconds:.1f}s")
    src = TaskTokenSource("serve", cfg.vocab_size, seed=0)
    if cfg.frontend != "none":
        print(f"{cfg.name}: modality frontend is stubbed; serving over "
              "token prompts against the decoder backbone")
    shared = (src.sample(1, args.shared_prefix)[0]
              if args.shared_prefix else None)
    t0 = time.time()
    handles = []
    for _ in range(args.requests):
        tail = src.sample(1, max(args.prompt - args.shared_prefix, 1))[0]
        prompt = tail if shared is None else np.concatenate([shared, tail])
        handles.append(runtime.enqueue(Request(prompt=prompt,
                                               max_new_tokens=args.steps)))
    runtime.run()
    dt = time.time() - t0
    n_tok = sum(len(h.result()) for h in handles)
    pool = (f"paged[{runtime.allocator.n_blocks}x{runtime.block_size}]"
            if runtime.paged else f"dense[{args.slots}x{engine.max_len}]")
    cache = ("off" if runtime.prefix_cache is None else
             f"hit_rate={runtime.prefix_hit_rate:.2f} "
             f"tokens_skipped={runtime.prefix_tokens_skipped} "
             f"cow={runtime.cow_copies}")
    print(f"{cfg.name}: served {len(handles)} requests / {n_tok} tokens in "
          f"{dt:.1f}s ({n_tok / dt:.1f} tok/s) pool={pool} "
          f"peak_batch={runtime.max_concurrency} "
          f"peak_admitted={runtime.max_admitted} "
          f"deferrals={runtime.deferrals} "
          f"prefix_cache[{cache}] "
          f"migrations={len(runtime.migrations)}")
    if args.warmup:
        p = runtime.perf_metrics()
        print(f"zero-stall: traces_after_warmup={p['traces_after_warmup']} "
              f"host_syncs={p['host_syncs']} "
              f"decode_round_ms p50={p['decode_round_ms']['p50']:.2f} "
              f"p99={p['decode_round_ms']['p99']:.2f} "
              f"ttft_ms p50={p['ttft_ms']['p50']:.2f}")
    if tracer is not None:
        obs = tracer.summary()
        tracer.export(args.trace_out)
        print(f"trace: {obs['events']} spans "
              f"(dropped={obs['dropped_events']}, "
              f"overhead={obs['overhead_ms']:.2f}ms) -> {args.trace_out}")


if __name__ == "__main__":
    main()
